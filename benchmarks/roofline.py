"""Roofline analysis (deliverable (g)) — derives the three roofline terms
per (arch × shape) from the dry-run's compiled artifact.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = Σ collective wire-bytes per device / link_bw

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed — both already
per-partition after SPMD) and the post-optimization HLO text for
collectives.  Collective bytes are NOT in cost_analysis: we parse the HLO
computation graph, sum the result bytes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute, multiply ops inside
``while`` bodies by their trip count (parsed from the loop condition — a
jax scan compares the induction variable against a constant), and weight
each op by its ring wire factor (all-reduce 2·(n−1)/n, gather/scatter
(n−1)/n, permute 1).

Methodology caveats recorded in EXPERIMENTS.md:
  * XLA:CPU promotes bf16 temporaries to f32 (FloatNormalization), so
    memory-term bytes overstate a TPU run by up to 2× on bf16-heavy cells;
    the stablehlo-level dtype census is the honest reference.
  * Hardware constants are TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI.

    PYTHONPATH=src python -m benchmarks.roofline --artifacts artifacts/dryrun
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import pathlib
import re
import sys
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 / chip
PEAK_INT8_OPS = 394e12    # int8 MXU / chip (2x the bf16 rate on v5e)
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# ring wire factor: bytes crossing a link per result-byte (n-large limit)
WIRE_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


# --------------------------------------------------------------- HLO parsing
#
# IMPORTANT: XLA's compiled.cost_analysis() counts while-loop bodies ONCE —
# for scan-over-layers models that understates flops/bytes by the trip
# count (~40-300x).  The mini cost model below re-derives loop-adjusted
# flops (from dot shapes × contraction dims), bytes (operands+results of
# top-level ops, matching HloCostAnalysis's convention), and collective
# wire bytes, multiplying everything inside a while body by its trip count.


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    name = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", stripped)
        if m and not line.startswith(" "):
            name = m.group(1)
            comps[name] = []
            continue
        if name is not None:
            if stripped == "}":
                name = None
            else:
                comps[name].append(stripped)
    return comps


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[dims] shape literal in `text`."""
    total = 0
    for m in re.finditer(r"(\w+)\[([0-9,]*)\]", text):
        dt = DTYPE_BYTES.get(m.group(1))
        if dt is None:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x]
        total += dt * (math.prod(dims) if dims else 1)
    return total


def _result_bytes(line: str) -> int:
    """Bytes of the result shape(s) of an instruction line."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1]
    # result type = everything before the opcode token
    for op in COLLECTIVES + ("while", "fusion", "call"):
        idx = rhs.find(f" {op}(") if not rhs.startswith(f"{op}(") else 0
        if rhs.startswith(f"{op}("):
            return 0
        if idx > 0:
            return _shape_bytes(rhs[:idx])
    return _shape_bytes(rhs.split("(", 1)[0])


def _trip_count(cond_lines: List[str]) -> int:
    """Largest integer constant in the while condition (jax scan: iv < N)."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")


def _parse_shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        if m.group(1) in DTYPE_BYTES:
            out.append((m.group(1), [int(x) for x in m.group(2).split(",") if x]))
    return out


def _build_def_table(lines: List[str], signature: str) -> Dict[str, List[Tuple[str, List[int]]]]:
    """Map %name -> result shapes, including computation parameters."""
    table: Dict[str, List[Tuple[str, List[int]]]] = {}
    # params from the signature: "(p0: f32[4,8], p1: (f32[2], s32[2]))"
    for m in re.finditer(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\))|(?:\w+\[[0-9,]*\](?:\{[^}]*\})?))",
                         signature):
        table[m.group(1)] = _parse_shapes(m.group(2))
    for line in lines:
        if " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        name = lhs.strip().lstrip("%")
        # result type(s) = prefix of rhs before the opcode token
        opsplit = re.match(r"((?:\([^)]*\))|(?:\w+\[[0-9,]*\](?:\{[^}]*\})?))\s+\w",
                           rhs)
        head = opsplit.group(1) if opsplit else rhs.split("(", 1)[0]
        table[name] = _parse_shapes(head)
    return table


_SKIP_BYTES_OPS = {
    "parameter", "get-tuple-element", "tuple", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def hlo_cost(hlo: str) -> Dict[str, float]:
    """Loop-adjusted (flops, bytes, collective wire bytes) per device."""
    comps = split_computations(hlo)
    sigs: Dict[str, str] = {}
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(\(.*\))\s*->", line.strip())
        if m and not line.startswith(" "):
            sigs[m.group(1)] = m.group(2)
    tables = {name: _build_def_table(lines, sigs.get(name, ""))
              for name, lines in comps.items()}
    memo: Dict[str, Dict[str, float]] = {}

    def op_name_of(rhs: str) -> str:
        m = re.match(r"(?:(?:\([^)]*\))|(?:\w+\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)",
                     rhs)
        return m.group(1) if m else rhs.split("(")[0].strip()

    def shapes_bytes(shapes) -> int:
        return sum(DTYPE_BYTES[d] * (math.prod(dims) if dims else 1)
                   for d, dims in shapes)

    def visit(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
        table = tables[name]
        acc = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
        fused_body = name.startswith("fused_") or name.startswith("wrapped_")
        for line in comps[name]:
            if " = " not in line:
                continue
            lhs, rhs = line.split(" = ", 1)
            op = op_name_of(rhs)
            res_shapes = table.get(lhs.strip().lstrip("%"), [])
            res_bytes = shapes_bytes(res_shapes)

            # ---- flops: dots (counted wherever they live, incl. fusions)
            if op == "dot":
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                ops = re.findall(r"%([\w\.\-]+)", rhs.split("(", 1)[1])
                lhs_shape = None
                if ops:
                    sh = table.get(ops[0])
                    if sh:
                        lhs_shape = sh[0][1]
                contracted = 1
                if cm and lhs_shape is not None:
                    for dim in (int(x) for x in cm.group(1).split(",") if x):
                        if dim < len(lhs_shape):
                            contracted *= lhs_shape[dim]
                n_res = sum(math.prod(d) if d else 1 for _, d in res_shapes)
                acc["flops"] += 2.0 * n_res * contracted

            # ---- collectives
            matched = next((c for c in COLLECTIVES
                            if f" {c}(" in line or f" {c}-start(" in line), None)
            if matched:
                acc["coll"] += res_bytes * WIRE_FACTOR[matched]

            # ---- bytes: top-level dataflow ops only (fusion internals are
            # register/VMEM traffic, matching HloCostAnalysis convention).
            # Sliced/gathered accesses charge the bytes actually touched,
            # not the full operand (in-place DUS on a scan carry would
            # otherwise charge the whole stacked buffer every iteration).
            if not fused_body and op not in _SKIP_BYTES_OPS:
                operand_names = re.findall(r"%([\w\.\-]+)", rhs.split("(", 1)[1]) \
                    if "(" in rhs else []
                operand_names = [o for o in operand_names
                                 if not o.startswith(("fused_", "wrapped_",
                                                      "region_"))]
                if op == "dynamic-update-slice":
                    upd = (shapes_bytes(table.get(operand_names[1], []))
                           if len(operand_names) > 1 else 0)
                    acc["bytes"] += 2 * upd
                elif op == "dynamic-slice":
                    acc["bytes"] += 2 * res_bytes
                elif op == "gather":
                    idx_b = (shapes_bytes(table.get(operand_names[1], []))
                             if len(operand_names) > 1 else 0)
                    acc["bytes"] += 2 * res_bytes + idx_b
                elif op in ("scatter", "scatter-add"):
                    upd = (shapes_bytes(table.get(operand_names[2], []))
                           if len(operand_names) > 2 else res_bytes)
                    idx_b = (shapes_bytes(table.get(operand_names[1], []))
                             if len(operand_names) > 1 else 0)
                    acc["bytes"] += 3 * upd + idx_b  # read-mod-write + read upd
                else:
                    ob = sum(shapes_bytes(table.get(o, []))
                             for o in operand_names)
                    acc["bytes"] += res_bytes + ob

            # ---- control flow
            wm = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", rhs)
            if wm and " while(" in line:
                trip = _trip_count(comps.get(wm.group(1), []))
                sub = visit(wm.group(2), stack + (name,))
                cnd = visit(wm.group(1), stack + (name,))
                for k in acc:
                    acc[k] += trip * (sub[k] + cnd[k])
                continue
            for cm2 in re.finditer(
                r"(?:calls|to_apply|branch_computations=\{)%?([\w\.\-]+)", rhs
            ):
                sub = visit(cm2.group(1), stack + (name,))
                for k in acc:
                    acc[k] += sub[k]
        memo[name] = acc
        return acc

    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w\.\-]+)", line)
        if m:
            entry = m.group(1)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    return visit(entry)


def collective_bytes(hlo: str) -> Tuple[float, Dict[str, float]]:
    """Wire bytes per device across the whole program (loop-adjusted).

    Returns (total, per-op-kind breakdown)."""
    comps = split_computations(hlo)
    memo: Dict[str, Tuple[float, Dict[str, float]]] = {}

    def visit(name: str, stack=()) -> Tuple[float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, {}
        total = 0.0
        kinds: Dict[str, float] = {}
        for line in comps[name]:
            matched = None
            for op in COLLECTIVES:
                if f" {op}(" in line or f"{op}-start(" in line:
                    matched = op
                    break
            if matched:
                b = _result_bytes(line) * WIRE_FACTOR[matched]
                total += b
                kinds[matched] = kinds.get(matched, 0.0) + b
                continue
            m = re.search(r"while\(.*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                          line)
            if not m:
                m2 = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", line)
                m = m2 if (m2 and " while(" in line) else None
            if m:
                trip = _trip_count(comps.get(m.group(1), []))
                sub, sk = visit(m.group(2), stack + (name,))
                total += trip * sub
                for k, v in sk.items():
                    kinds[k] = kinds.get(k, 0.0) + trip * v
                continue
            for cm in re.finditer(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)",
                                  line):
                if " while(" in line:
                    continue
                sub, sk = visit(cm.group(1), stack + (name,))
                total += sub
                for k, v in sk.items():
                    kinds[k] = kinds.get(k, 0.0) + v
        memo[name] = (total, kinds)
        return memo[name]

    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w\.\-]+)", line)
        if m:
            entry = m.group(1)
            break
    if entry is None:
        return 0.0, {}
    return visit(entry)


# --------------------------------------------------------- model-flops (6ND)
def model_flops(arch: str, shape: str, meta: dict) -> Optional[float]:
    """Analytic 'useful' FLOPs per step (whole program, all devices)."""
    from repro.models import registry

    cell_cfg = meta.get("cfg")
    if arch in ("command-r-35b", "gemma2-27b", "qwen3-1.7b",
                "qwen3-moe-30b-a3b", "llama4-scout-17b-a16e"):
        cfg = cell_cfg
        n_active = _lm_active_params(cfg)
        tokens = meta["batch"] * (meta["seq"] if shape != f"decode_32k" else 1)
        if shape.startswith("decode"):
            tokens = meta["batch"]
        factor = 6 if shape.startswith("train") else 2
        return factor * n_active * tokens
    if arch == "nequip":
        return _nequip_flops(cell_cfg, meta) * 3  # fwd+bwd ~ 3x fwd
    if arch == "compressae":
        cfg = cell_cfg
        if shape == "train_100k":
            # fwd: 1 encode (2dhB) + 2 decodes (k & 4k share pre-acts);
            # bwd ≈ 2x fwd -> 18·d·h·B
            return 18 * cfg.d * cfg.h * meta["batch"]
        if shape == "compress_1m":      # encode only
            return 2 * cfg.d * cfg.h * meta["batch"]
        # retrieval handled by the 'compressed' variant branch below
    return _recsys_flops(arch, cell_cfg, meta)


def _lm_active_params(cfg) -> float:
    d, hd = cfg.d_model, cfg.hd
    attn = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2
    if cfg.moe is None:
        ffn = 3 * d * cfg.d_ff
    else:
        m = cfg.moe
        ffn = 3 * d * m.d_ff_expert * (m.top_k + m.n_shared)
    per_layer = attn + ffn
    embed = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * per_layer + embed


def _nequip_flops(cfg, meta) -> float:
    n, e = meta["n_nodes"], meta["n_edges"]
    c = cfg.d_hidden
    tp = sum(
        2 * c * (2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1)
        for (l1, l2, l3) in cfg.paths
    )
    radial = 2 * cfg.n_rbf * cfg.radial_hidden + \
        2 * cfg.radial_hidden * len(cfg.paths) * c
    self_int = sum(2 * c * c * (2 * l + 1) for l in cfg.ls) * 2
    return cfg.n_layers * (e * (tp + radial) + n * self_int)


def _recsys_flops(arch: str, cfg, meta) -> float:
    # compressed retrieval (the paper's path): useful work = the sparse-dot
    # SpMV (2k flops/candidate) + the query encode, NOT a full model pass
    if meta.get("variant") == "compressed":
        sae = meta["sae"]
        n_cand = meta["n_candidates"]
        encode_flops = 2 * sae.d * sae.h
        return 2 * sae.k * n_cand + encode_flops
    b = meta.get("n_candidates", meta.get("batch", 1))
    factor = 3 if "train" in str(meta.get("kind", "")) else 1

    def mlp(sizes):
        return sum(2 * a * bb for a, bb in zip(sizes[:-1], sizes[1:]))

    if arch == "dlrm-mlperf":
        n_f = cfg.n_sparse + 1
        per = mlp([cfg.n_dense, *cfg.bot_mlp]) + \
            2 * n_f * n_f * cfg.embed_dim + \
            mlp([cfg.bot_mlp[-1] + n_f * (n_f - 1) // 2, *cfg.top_mlp])
    elif arch == "deepfm":
        per = 4 * cfg.n_sparse * cfg.embed_dim + \
            mlp([cfg.n_sparse * cfg.embed_dim, *cfg.mlp, 1])
    elif arch == "din":
        d = cfg.embed_dim
        per = cfg.seq_len * (mlp([4 * d, *cfg.attn_mlp, 1]) + 2 * d) + \
            mlp([3 * d, *cfg.mlp, 1])
    else:  # bert4rec
        d = cfg.embed_dim
        per_tok = 2 * (4 * d * d) + 2 * 2 * d * cfg.d_ff + 4 * cfg.seq_len * d
        per = cfg.seq_len * per_tok / max(meta.get("n_candidates", 1) and 1, 1)
        per = cfg.seq_len * per_tok
    return per * b * factor


# ------------------------------------------------- retrieval traffic model
def quantized_row_bytes(k: int, h: int) -> int:
    """Index bytes per candidate row in the compound-compressed serving
    format: int8 values + int16/int32 indices + one f32 per-row dequant
    scale.  Mirrors ``QuantizedCodes.nbytes_logical`` arithmetic (int16
    indices whenever h < 65536)."""
    idx_b = 2 if h < 65536 else 4
    return k * (1 + idx_b) + 4


def retrieval_traffic(
    n: int = 100_000, k: int = 32, q: int = 64, topn: int = 20,
    block_q: int = 8, h: int = 4096,
) -> Dict[str, Dict[str, float]]:
    """Analytic HBM traffic (+ scoring-compute terms) for the retrieval
    generations.  All serve Q queries over N fixed-k candidates; f32 codes
    are 8 B per nonzero, quantized rows are ``quantized_row_bytes(k, h)``
    (~3k+4 vs 8k), and every path streams 4 B/row of reciprocal norms:

      per_query       — seed kernel: grid (Q, N/BLOCK_N) streams every
                        candidate tile once PER QUERY, then writes the full
                        (Q, N) score matrix to HBM and re-reads it for
                        lax.top_k.
      blocked         — multi-query panel: candidates stream once per
                        BLOCK_Q queries; (Q, N) scores still round-trip HBM.
      fused           — blocked scoring + streaming top-n epilogue in VMEM:
                        only (Q, topn) scores+ids ever reach HBM.
      fused_quantized — generation 4: the candidate stream is the
                        compound-compressed format itself (+ 4 B/row of
                        dequant scales), dequantized in VMEM — same f32
                        scoring compute, ~2.6x less index traffic at k=32.
      fused_quantized_mxu — generation 5: identical HBM bytes to
                        fused_quantized (the int8 tiles are what streams
                        either way; the query panel quantizes in VMEM, so
                        int8 scoring adds NO HBM traffic) but the scoring
                        contraction runs at the int8 MXU rate — the
                        compute term halves, which is the whole point of
                        scoring without dequantizing.

    Each row carries bytes / bytes_per_row / t_mem_ms / t_comp_ms /
    speedup_vs_per_query (HBM-traffic ratio — the roofline bound for
    these memory-bound shapes).
    """
    cand = n * k * 8                       # f32 values + i32 indices
    cand_q = n * quantized_row_bytes(k, h)
    norms = n * 4
    score_rt = q * n * 4 * 2               # write + re-read for top-k
    out = q * topn * 8                     # scores + ids
    panels = -(-q // block_q)              # ceil(Q / BLOCK_Q)
    flops = 2.0 * q * n * k                # the scoring contraction
    variants = {
        "per_query": (cand * q + norms + score_rt + out, cand, PEAK_FLOPS),
        "blocked": (cand * panels + norms + score_rt + out, cand, PEAK_FLOPS),
        "fused": (cand * panels + norms + out, cand, PEAK_FLOPS),
        "fused_quantized": (cand_q * panels + norms + out, cand_q,
                            PEAK_FLOPS),
        "fused_quantized_mxu": (cand_q * panels + norms + out, cand_q,
                                PEAK_INT8_OPS),
    }
    base = variants["per_query"][0]
    return {
        name: {
            "bytes": float(b),
            "bytes_per_row": cand_bytes / n + 4,   # + reciprocal norm
            "t_mem_ms": b / HBM_BW * 1e3,
            "t_comp_ms": flops / peak * 1e3,
            "speedup_vs_per_query": base / b,
        }
        for name, (b, cand_bytes, peak) in variants.items()
    }


def retrieval_traffic_report(n=100_000, k=32, q=64, topn=20, block_q=8,
                             h=4096) -> str:
    rows = retrieval_traffic(n, k, q, topn, block_q, h)
    idx_dtype = "int16" if h < 65536 else "int32"
    out = [f"retrieval HBM traffic model: N={n} k={k} Q={q} topn={topn} "
           f"BLOCK_Q={block_q} h={h} (HBM {HBM_BW/1e9:.0f} GB/s, "
           f"f32 {PEAK_FLOPS/1e12:.0f} TFLOP/s, "
           f"int8 {PEAK_INT8_OPS/1e12:.0f} TOP/s; quantized index rows: "
           f"int8 values + {idx_dtype} indices + f32 scale = "
           f"{quantized_row_bytes(k, h)} B vs fp32 codes {8 * k} B)",
           "| path | HBM bytes | B/row | t_mem (ms) | t_comp (ms) "
           "| speedup |",
           "|---|---|---|---|---|---|"]
    for name, r in rows.items():
        out.append(f"| {name} | {r['bytes']:.3e} | {r['bytes_per_row']:.0f} "
                   f"| {r['t_mem_ms']:.3f} | {r['t_comp_ms']:.3f} "
                   f"| {r['speedup_vs_per_query']:.1f}x |")
    return "\n".join(out)


# -------------------------------------------------------------------- report
@dataclasses.dataclass
class Row:
    arch: str
    shape: str
    flops: float
    bytes_: float
    coll: float
    t_compute: float
    t_mem: float
    t_coll: float
    bottleneck: str
    mf_ratio: Optional[float]
    coll_kinds: Dict[str, float]


def analyze(artifacts: pathlib.Path, mesh_tag: str = "singlepod",
            n_devices: int = 256) -> List[Row]:
    from repro.models import registry

    rows = []
    for jf in sorted(artifacts.glob(f"*__{mesh_tag}.json")):
        rec = json.loads(jf.read_text())
        if "error" in rec or "skip" in rec:
            continue
        arch, shape = rec["arch"], rec["shape"]
        # raw cost_analysis numbers are NOT loop-adjusted (while bodies
        # counted once) — keep them for cross-checking only
        flops = rec["cost"]["flops"] or 0.0
        bytes_ = rec["cost"]["bytes_accessed"] or 0.0
        hlo_file = jf.with_name(jf.name.replace(".json", ".hlo.txt"))
        coll, kinds = (0.0, {})
        if hlo_file.exists():
            hlo = hlo_file.read_text()
            cost = hlo_cost(hlo)
            flops = max(flops, cost["flops"])
            bytes_ = max(bytes_, cost["bytes"])
            coll, kinds = collective_bytes(hlo)
        t_c = flops / PEAK_FLOPS
        t_m = bytes_ / HBM_BW
        t_x = coll / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        bottleneck = max(terms, key=terms.get)
        cell = registry.build_cell(arch, shape, full=True)
        mf = None
        if cell.meta:
            cell.meta.setdefault("kind", cell.kind)
            try:
                mf_total = model_flops(arch, shape, cell.meta)
                if mf_total:
                    mf = mf_total / (flops * n_devices) if flops else None
            except Exception:
                mf = None
        rows.append(Row(arch, shape, flops, bytes_, coll, t_c, t_m, t_x,
                        bottleneck, mf, kinds))
    return rows


def to_markdown(rows: List[Row]) -> str:
    out = [
        "| arch | shape | FLOPs/dev | bytes/dev | coll B/dev | t_comp (ms) "
        "| t_mem (ms) | t_coll (ms) | bound | useful-FLOPs ratio |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        mf = f"{r.mf_ratio:.2f}" if r.mf_ratio else "—"
        out.append(
            f"| {r.arch} | {r.shape} | {r.flops:.2e} | {r.bytes_:.2e} "
            f"| {r.coll:.2e} | {r.t_compute*1e3:.2f} | {r.t_mem*1e3:.2f} "
            f"| {r.t_coll*1e3:.2f} | **{r.bottleneck}** | {mf} |"
        )
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="singlepod")
    ap.add_argument("--out", default="artifacts/roofline.md")
    ap.add_argument("--retrieval", action="store_true",
                    help="print the analytic retrieval HBM-traffic model "
                         "(per-query vs blocked vs fused kernel) and exit")
    args = ap.parse_args(argv)
    if args.retrieval:
        print(retrieval_traffic_report())
        return 0
    rows = analyze(pathlib.Path(args.artifacts), args.mesh)
    md = to_markdown(rows)
    print(md)
    if args.out:
        pathlib.Path(args.out).write_text(md + "\n")
        import json as _json

        blob = [dataclasses.asdict(r) for r in rows]
        pathlib.Path(args.out).with_suffix(".json").write_text(
            _json.dumps(blob, indent=2)
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
