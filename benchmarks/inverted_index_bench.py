"""Beyond-paper: inverted-file sparse retrieval vs the exact scan.

Measures the work reduction (fraction of catalog scanned per query) and
the recall cost of posting-list capping, vs the paper's exact O(N·k) scan.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, build_index, encode, init_train_state, score_dense,
    score_sparse, top_n, train_step,
)
from repro.core.inverted_index import (
    build_inverted_index, expected_scan_fraction, search_inverted,
)
from repro.data import clustered_embeddings
from repro.optim import AdamConfig

D, H, K = 256, 1024, 16
N, Q, TOPN = 8192, 64, 10


def main():
    cfg = SAEConfig(d=D, h=H, k=K)
    corpus = clustered_embeddings(jax.random.PRNGKey(0), N, d=D)
    queries = clustered_embeddings(jax.random.PRNGKey(1), Q, d=D)
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(250):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(3), i),
                                 (2048,), 0, N)
        state, _ = step(state, corpus[idx])
    params = state.params
    codes = encode(params, corpus, cfg.k)
    q_codes = encode(params, queries, cfg.k)
    exact = build_index(codes)
    truth = top_n(score_sparse(exact, q_codes), TOPN)[1]   # exact sparse scan

    print("name,us_per_call,derived")
    for cap in (256, 1024, 4096):
        inv = build_inverted_index(codes, cap=cap)
        frac = expected_scan_fraction(codes, cap)
        _, ids = search_inverted(inv, q_codes, TOPN)
        rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / TOPN
                       for a, b in zip(np.asarray(ids), np.asarray(truth))])
        print(f"inverted_cap{cap},0,scan_frac={frac:.3f};"
              f"recall_vs_exact_scan={rec:.3f}")
    # uncapped lists must reproduce the exact scan ordering
    inv_full = build_inverted_index(codes, cap=N)
    _, ids_full = search_inverted(inv_full, q_codes, TOPN)
    rec_full = np.mean([len(set(a.tolist()) & set(b.tolist())) / TOPN
                        for a, b in zip(np.asarray(ids_full), np.asarray(truth))])
    print(f"inverted_uncapped,0,recall_vs_exact_scan={rec_full:.3f}")
    assert rec_full > 0.999, rec_full
    return 0


if __name__ == "__main__":
    main()
