"""Beyond-paper: inverted-file sparse retrieval vs the exact scan.

Measures the work reduction (fraction of catalog scanned per query) and
the recall cost of posting-list capping, vs the paper's exact O(N·k)
scan — and, at full size, the single-stage vs two-stage N-sweep whose
crossover docs/BENCHMARKS.md snapshots.

Since ISSUE 7 this bench is part of the schema-gated BENCH flow: it
APPENDS one ``retrieval_inverted_index`` row to ``BENCH_retrieval.json``
(the candidate-generation quality at the serving cap — scan fraction +
recall vs the exact sparse scan), so ``tools/check_bench.py`` gates it
like every other row.  It must therefore run AFTER
``benchmarks.retrieval_modes``, which rewrites the record wholesale.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, build_index, encode, init_train_state, score_sparse,
    top_n, train_step,
)
from repro.core.inverted_index import (
    build_inverted_index, expected_scan_fraction, search_inverted,
)
from repro.core.retrieval import kernel_path, retrieve, two_stage_retrieve
from repro.data import clustered_embeddings
from repro.optim import AdamConfig

D, H, K = 256, 1024, 16
N, Q, TOPN = 8192, 64, 10
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_retrieval.json"


def _timeit(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def _train(cfg, corpus, n, steps):
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(steps):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(3), i),
                                 (min(2048, n),), 0, n)
        state, _ = step(state, corpus[idx])
    return state.params


def main(smoke: bool = False):
    n, q_count, topn = (1024, 16, 5) if smoke else (N, Q, TOPN)
    train_steps = 40 if smoke else 250
    caps = (64, 256) if smoke else (256, 1024, 4096)
    serving_cap = caps[-1]
    cfg = SAEConfig(d=D, h=H, k=K)
    corpus = clustered_embeddings(jax.random.PRNGKey(0), n, d=D)
    queries = clustered_embeddings(jax.random.PRNGKey(1), q_count, d=D)
    params = _train(cfg, corpus, n, train_steps)
    codes = encode(params, corpus, cfg.k)
    q_codes = encode(params, queries, cfg.k)
    exact = build_index(codes)
    truth = top_n(score_sparse(exact, q_codes), topn)[1]   # exact sparse scan

    def rec_vs_exact(ids):
        return float(np.mean([
            len(set(a.tolist()) & set(b.tolist())) / topn
            for a, b in zip(np.asarray(ids), np.asarray(truth))
        ]))

    print("name,us_per_call,derived")
    serving_row = None
    for cap in caps:
        inv = build_inverted_index(codes, cap=cap)
        frac = expected_scan_fraction(codes, cap)
        us = _timeit(lambda qc: search_inverted(inv, qc, topn), q_codes)
        rec = rec_vs_exact(search_inverted(inv, q_codes, topn)[1])
        print(f"inverted_cap{cap},{us:.0f},scan_frac={frac:.3f};"
              f"recall_vs_exact_scan={rec:.3f}")
        if cap == serving_cap:
            serving_row = (us, rec, frac)
    # uncapped lists must reproduce the exact scan ordering
    inv_full = build_inverted_index(codes, cap=n)
    _, ids_full = search_inverted(inv_full, q_codes, topn)
    rec_full = rec_vs_exact(ids_full)
    print(f"inverted_uncapped,0,recall_vs_exact_scan={rec_full:.3f}")
    assert rec_full > 0.999, rec_full

    # ---- the schema-gated BENCH row (appended; see module docstring) ----
    us, rec, frac = serving_row
    record = {
        "name": "retrieval_inverted_index",
        "us_per_call": round(us, 1),
        # recall here is vs the exact sparse scan — the candidate
        # generator's own quality bound (check_bench gates recall* drops)
        "recall": round(rec, 4),
        "path": "fused-kernel" if kernel_path("auto") else "jnp-chunked",
        "shards": 1, "n": n, "q": q_count, "topn": topn, "smoke": smoke,
        "cap": serving_cap, "scan_frac": round(frac, 4),
    }
    records = json.loads(BENCH_JSON.read_text()) if BENCH_JSON.exists() else []
    records = [r for r in records if r["name"] != record["name"]]
    records.append(record)
    BENCH_JSON.write_text(json.dumps(records, indent=2) + "\n")
    print(f"[bench] appended retrieval_inverted_index to {BENCH_JSON}")

    # ---- N-sweep: single-stage vs two-stage crossover (full size only;
    # docs/BENCHMARKS.md snapshots this table).  One model serves every
    # N — corpora are re-encoded, the SAE is not re-trained per size.
    if not smoke:
        print("sweep_n,single_us,two_stage_us")
        for n_sweep in (2048, 8192, 16384, 32768):
            corpus_s = clustered_embeddings(jax.random.PRNGKey(4), n_sweep,
                                            d=D)
            codes_s = encode(params, corpus_s, cfg.k)
            index_s = build_index(codes_s)
            inv_s = build_inverted_index(codes_s, cap=serving_cap)
            single_fn = jax.jit(
                lambda qc, idx=index_s: retrieve(idx, qc, topn,
                                                 use_kernel=False))
            cache = {}
            two_fn = lambda qc, idx=index_s, iv=inv_s: two_stage_retrieve(  # noqa: E731
                idx, iv, qc, topn, use_fused=False,
                candidate_fraction=0.25, cache=cache)
            us_1 = _timeit(single_fn, q_codes)
            us_2 = _timeit(two_fn, q_codes)
            print(f"sweep_{n_sweep},{us_1:.0f},{us_2:.0f}")
    return 0


if __name__ == "__main__":
    main()
