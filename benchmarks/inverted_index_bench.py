"""Beyond-paper: inverted-file sparse retrieval vs the exact scan.

Measures the work reduction (fraction of catalog scanned per query) and
the recall cost of posting-list capping, vs the paper's exact O(N·k)
scan — and the single-stage vs two-stage N-sweep whose crossover
docs/BENCHMARKS.md snapshots, up to N >= 1M with the ISSUE 8 device
stage-1 + batched stage-2 path (smoke runs keep a single tiny sweep row
so CI still exercises the code path).

Since ISSUE 7 this bench is part of the schema-gated BENCH flow: it
APPENDS one ``retrieval_inverted_index`` row to ``BENCH_retrieval.json``
(the candidate-generation quality at the serving cap — scan fraction +
recall vs the exact sparse scan), so ``tools/check_bench.py`` gates it
like every other row.  It must therefore run AFTER
``benchmarks.retrieval_modes``, which rewrites the record wholesale.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, build_index, encode, init_train_state, score_sparse,
    top_n, train_step,
)
from repro.core.inverted_index import (
    build_inverted_index, candidate_union, device_candidate_union,
    expected_scan_fraction, search_inverted,
)
from repro.core.retrieval import (
    kernel_path, retrieve, two_stage_budget, two_stage_retrieve,
)
from repro.data import clustered_embeddings
from repro.optim import AdamConfig

D, H, K = 256, 1024, 16
N, Q, TOPN = 8192, 64, 10
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_retrieval.json"


def _timeit(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def _train(cfg, corpus, n, steps):
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(steps):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(3), i),
                                 (min(2048, n),), 0, n)
        state, _ = step(state, corpus[idx])
    return state.params


def main(smoke: bool = False):
    n, q_count, topn = (1024, 16, 5) if smoke else (N, Q, TOPN)
    train_steps = 40 if smoke else 250
    caps = (64, 256) if smoke else (256, 1024, 4096)
    serving_cap = caps[-1]
    cfg = SAEConfig(d=D, h=H, k=K)
    corpus = clustered_embeddings(jax.random.PRNGKey(0), n, d=D)
    queries = clustered_embeddings(jax.random.PRNGKey(1), q_count, d=D)
    params = _train(cfg, corpus, n, train_steps)
    codes = encode(params, corpus, cfg.k)
    q_codes = encode(params, queries, cfg.k)
    exact = build_index(codes)
    truth = top_n(score_sparse(exact, q_codes), topn)[1]   # exact sparse scan

    def rec_vs_exact(ids):
        return float(np.mean([
            len(set(a.tolist()) & set(b.tolist())) / topn
            for a, b in zip(np.asarray(ids), np.asarray(truth))
        ]))

    print("name,us_per_call,derived")
    serving_row = None
    for cap in caps:
        inv = build_inverted_index(codes, cap=cap)
        frac = expected_scan_fraction(codes, cap)
        us = _timeit(lambda qc: search_inverted(inv, qc, topn), q_codes)
        rec = rec_vs_exact(search_inverted(inv, q_codes, topn)[1])
        print(f"inverted_cap{cap},{us:.0f},scan_frac={frac:.3f};"
              f"recall_vs_exact_scan={rec:.3f}")
        if cap == serving_cap:
            serving_row = (us, rec, frac)
    # uncapped lists must reproduce the exact scan ordering
    inv_full = build_inverted_index(codes, cap=n)
    _, ids_full = search_inverted(inv_full, q_codes, topn)
    rec_full = rec_vs_exact(ids_full)
    print(f"inverted_uncapped,0,recall_vs_exact_scan={rec_full:.3f}")
    assert rec_full > 0.999, rec_full

    # ---- the schema-gated BENCH row (appended; see module docstring) ----
    us, rec, frac = serving_row
    record = {
        "name": "retrieval_inverted_index",
        "us_per_call": round(us, 1),
        # recall here is vs the exact sparse scan — the candidate
        # generator's own quality bound (check_bench gates recall* drops)
        "recall": round(rec, 4),
        "path": "fused-kernel" if kernel_path("auto") else "jnp-chunked",
        "shards": 1, "n": n, "q": q_count, "topn": topn, "smoke": smoke,
        "cap": serving_cap, "scan_frac": round(frac, 4),
    }
    records = json.loads(BENCH_JSON.read_text()) if BENCH_JSON.exists() else []
    records = [r for r in records if r["name"] != record["name"]]
    records.append(record)
    BENCH_JSON.write_text(json.dumps(records, indent=2) + "\n")
    print(f"[bench] appended retrieval_inverted_index to {BENCH_JSON}")

    # ---- N-sweep: single-stage vs two-stage crossover up to N >= 1M
    # (docs/BENCHMARKS.md snapshots this table).  One model serves every
    # N — corpora are re-encoded (chunked: the 1M corpus would otherwise
    # materialize a (N, H) activation transient), the SAE is not
    # re-trained per size.  Per row:
    #   single_us   — the exact one-stage scan (chunked jnp)
    #   two_dev_us  — ISSUE 8 path: device stage-1 union + ONE batched
    #                 gathered re-rank (no per-query host work at all)
    #   two_pr7_us  — ISSUE 7 path: host stage-1 + per-query stage-2
    #                 loop (kept as the parity oracle)
    #   s1_dev_us / s1_host_us — stage 1 alone, device vs host: the
    #                 device column must stop scaling with per-query
    #                 Python work (that is the tentpole's point)
    # Larger N rows shrink Q and the candidate fraction to keep the
    # gathered (Q, budget, k) panels ~100 MB, not gigabytes.
    sweep = ([(2048, 0.4, 8)] if smoke else
             [(2048, 0.25, 64), (8192, 0.25, 64), (32768, 0.25, 64),
              (131072, 0.10, 32), (1048576, 0.05, 16)])
    print("sweep_n,single_us,two_dev_us,two_pr7_us,s1_dev_us,s1_host_us,"
          "cand_frac,q")
    for n_sweep, frac_s, q_s in sweep:
        corpus_s = clustered_embeddings(jax.random.PRNGKey(4), n_sweep, d=D)
        chunks = [encode(params, corpus_s[i:i + 65536], cfg.k)
                  for i in range(0, n_sweep, 65536)]
        codes_s = (chunks[0] if len(chunks) == 1 else type(chunks[0])(
            values=jnp.concatenate([c.values for c in chunks]),
            indices=jnp.concatenate([c.indices for c in chunks]),
            dim=chunks[0].dim))
        del corpus_s
        index_s = build_index(codes_s)
        inv_s = build_inverted_index(codes_s, cap=serving_cap)
        qc_s = type(q_codes)(values=q_codes.values[:q_s],
                             indices=q_codes.indices[:q_s], dim=q_codes.dim)
        budget = two_stage_budget(n_sweep, topn, frac_s)
        single_fn = jax.jit(
            lambda qc, idx=index_s: retrieve(idx, qc, topn,
                                             use_kernel=False))
        cache_dev, cache_pr7 = {}, {}
        two_dev = lambda qc, idx=index_s, iv=inv_s: two_stage_retrieve(  # noqa: E731
            idx, iv, qc, topn, use_fused=False, candidate_fraction=frac_s,
            cache=cache_dev, stage1="device", stage2="batched")
        two_pr7 = lambda qc, idx=index_s, iv=inv_s: two_stage_retrieve(  # noqa: E731
            idx, iv, qc, topn, use_fused=False, candidate_fraction=frac_s,
            cache=cache_pr7, stage1="host", stage2="per_query")
        s1_dev = lambda qi, iv=inv_s: device_candidate_union(  # noqa: E731
            iv, qi, budget)
        s1_host = lambda qi, iv=inv_s: candidate_union(  # noqa: E731
            iv, np.asarray(qi), budget)
        us_1 = _timeit(single_fn, qc_s)
        us_2d = _timeit(two_dev, qc_s)
        us_2h = _timeit(two_pr7, qc_s)
        us_s1d = _timeit(s1_dev, qc_s.indices)
        us_s1h = _timeit(s1_host, qc_s.indices)
        print(f"sweep_{n_sweep},{us_1:.0f},{us_2d:.0f},{us_2h:.0f},"
              f"{us_s1d:.0f},{us_s1h:.0f},{frac_s:g},{q_s}")
    return 0


if __name__ == "__main__":
    main()
